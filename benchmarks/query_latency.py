"""Paper Figure 4: average runtime of 500 queries per triple pattern on the
geo-coordinates-en stand-in, per engine (ITR vs k²-triples vs HDT-BT).

The paper's claim under test: ITR answers every pattern except ?P? faster
than (or comparable to) the baselines, in milliseconds.

Beyond the paper, `BENCH_query_latency.json` tracks the serving-perf
trajectory from PR 1 onward:

* per-pattern µs for the batched engine (`query_batch_arrays`) vs the seed
  per-query worklist (`query_scalar`), plus `batch_throughput_qps`;
* a `warm_cache` section — cold (cache-miss + insert) vs warm (all-hit)
  batch runs against the uncached baseline, exercising the cross-request
  result cache incl. its ?P? segment;
* a `crossover_dispatch` section — single-query latency of the dispatched
  `engine.query` vs the scalar worklist vs a forced frontier-of-one, per
  selective pattern, at the engine's calibrated crossover width.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import (
    BATCH_QUERIES_PER_PATTERN,
    PATTERNS,
    QUERIES_PER_PATTERN,
    bind_pattern,
    build_all,
    engine_cache_disabled,
    sample_rows,
    time_queries,
    time_query_batch,
)
from repro.data.synthetic import PAPER_DATASETS

# selective patterns: S or O bound — the ones eligible for scalar dispatch
DISPATCH_PATTERNS = ["s??", "sp?", "s?o", "??o", "spo"]
WARM_CACHE_PATTERNS = ["s??", "?p?", "sp?", "??o"]


def run(dataset="geo-coordinates-en", n_queries=500, quiet=False,
        json_path="BENCH_query_latency.json", scale=None):
    ds = PAPER_DATASETS[dataset]() if scale is None else PAPER_DATASETS[dataset](scale=scale)
    built = build_all(ds)
    built.pop("raw_bytes")
    itr = built["ITR"]["engine"]
    rows = []
    bench = {"dataset": dataset, "n_queries": n_queries, "patterns": {}}
    for pattern in PATTERNS:
        row = {"pattern": pattern}
        checks = {}
        for method, b in built.items():
            us, n_res = time_queries(b["engine"], ds, pattern, n_queries)
            row[method] = us
            checks[method] = n_res
        # seed per-query reference path (pre-batching worklist)
        scalar_us, scalar_n = time_queries(
            itr, ds, pattern, n_queries, query_fn=itr.query_scalar)
        checks["ITR-scalar"] = scalar_n
        # batched throughput on the full workload
        bat_us, bat_n, qps = time_query_batch(itr, ds, pattern, n_queries)
        # batched parity on the same capped sample as the per-query engines
        # (the timing run above already IS that sample unless caps differ)
        n_par = min(n_queries, QUERIES_PER_PATTERN.get(pattern, n_queries))
        n_bat = min(n_queries, BATCH_QUERIES_PER_PATTERN.get(pattern, n_queries))
        if n_par == n_bat:
            checks["ITR-batched"] = bat_n
        else:
            _, par_n, _ = time_query_batch(itr, ds, pattern, n_par)
            checks["ITR-batched"] = par_n
        # engines must agree on result counts (correctness guard)
        assert len(set(checks.values())) == 1, f"{pattern}: result mismatch {checks}"
        row["ITR-batched"] = bat_us
        speedup = scalar_us / bat_us if bat_us > 0 else float("inf")
        bench["patterns"][pattern] = {
            "scalar_us": scalar_us,
            "batched_us": bat_us,
            "speedup_vs_scalar": speedup,
            "batch_qps": qps,
            "n_results_batched": bat_n,
            "baseline_us": {m: row[m] for m in built},
        }
        rows.append(row)
        if not quiet:
            times = " ".join(f"{m}={row[m]:9.1f}us" for m in built)
            print(f"fig4 {pattern} {times} batched={bat_us:9.1f}us "
                  f"({speedup:5.1f}x vs scalar)  (n={checks['ITR']})")
    _bench_warm_cache(itr, ds, bench, n_queries, quiet)
    _bench_crossover(itr, ds, bench, n_queries, quiet)
    _finalize_throughput(bench, n_queries)
    if json_path:
        Path(json_path).write_text(json.dumps(bench, indent=2))
    if not quiet:
        print(f"batch_throughput_qps={bench['batch_throughput_qps']:.0f}"
              + (f" -> {json_path}" if json_path else " (not written)"))
    return rows


def _bench_warm_cache(itr, ds, bench: dict, n_queries: int, quiet: bool) -> None:
    """Streaming repeated-pattern serving: a hot set of patterns queried in
    micro-batches. In-batch dedup collapses repeats *within* one flush; only
    the cross-request cache collapses them *across* flushes — so the
    uncached baseline re-executes every micro-batch's unique patterns while
    the warm pass answers them all from the LRU. The acceptance bar is warm
    throughput >= 5x the uncached batch path on this workload.
    """
    if itr.cache is None:
        return
    hot, micro = 32, 32
    n_flushes = max(2, min(16, n_queries // micro))
    rng = np.random.default_rng(1)
    out = {}
    for pattern in WARM_CACHE_PATTERNS:
        pool = np.unique(sample_rows(ds, 4 * hot), axis=0)[:hot]
        batches = []
        for _ in range(n_flushes):
            picks = pool[rng.integers(0, len(pool), micro)]
            batches.append(bind_pattern(pattern, picks))
        total_q = n_flushes * micro

        def run_workload():
            t0 = time.perf_counter()
            for s_arr, p_arr, o_arr in batches:
                itr.query_batch_arrays(s_arr, p_arr, o_arr)
            return (time.perf_counter() - t0) / total_q * 1e6

        with engine_cache_disabled(itr):
            uncached_us = run_workload()
        itr.cache.clear()
        cold_us = run_workload()  # first flush misses, later flushes hit
        warm_us = run_workload()  # all-hit steady state
        out[pattern] = {
            "uncached_us": uncached_us,
            "cold_us": cold_us,
            "warm_us": warm_us,
            "warm_speedup_vs_uncached": uncached_us / warm_us if warm_us > 0 else float("inf"),
            "warm_qps": 1e6 / warm_us if warm_us > 0 else float("inf"),
        }
        if not quiet:
            print(f"cache {pattern} uncached={uncached_us:9.1f}us cold={cold_us:9.1f}us "
                  f"warm={warm_us:9.1f}us ({out[pattern]['warm_speedup_vs_uncached']:5.1f}x"
                  f" vs uncached batch)")
    # single-query point lookups: the purest repeated-pattern serving case
    s0, p0, o0 = (int(v) for v in sample_rows(ds, 1)[0])
    reps = 50
    with engine_cache_disabled(itr):
        t0 = time.perf_counter()
        for _ in range(reps):
            itr.query(s0, None, None)
        point_uncached_us = (time.perf_counter() - t0) / reps * 1e6
    itr.cache.clear()
    itr.query(s0, None, None)  # populate
    t0 = time.perf_counter()
    for _ in range(reps):
        itr.query(s0, None, None)
    point_warm_us = (time.perf_counter() - t0) / reps * 1e6
    agg_uncached = sum(p["uncached_us"] for p in out.values())
    agg_warm = sum(p["warm_us"] for p in out.values())
    st = itr.cache.stats
    bench["warm_cache"] = {
        "hot_patterns": hot,
        "micro_batch": micro,
        "n_flushes": n_flushes,
        "patterns": out,
        "aggregate_warm_speedup_vs_uncached":
            agg_uncached / agg_warm if agg_warm > 0 else float("inf"),
        "point_lookup": {
            "uncached_us": point_uncached_us,
            "warm_us": point_warm_us,
            "warm_speedup": point_uncached_us / point_warm_us if point_warm_us > 0 else float("inf"),
        },
        "cache_stats": {"hits": st.hits, "misses": st.misses,
                        "evictions": st.evictions, "inserts": st.inserts,
                        "predicate_hits": st.predicate_hits,
                        "hit_rate": st.hit_rate},
    }
    if not quiet:
        print(f"cache point-lookup uncached={point_uncached_us:9.1f}us "
              f"warm={point_warm_us:9.1f}us "
              f"({bench['warm_cache']['point_lookup']['warm_speedup']:5.1f}x)")


def _bench_crossover(itr, ds, bench: dict, n_queries: int, quiet: bool) -> None:
    """Single-query latency per selective pattern: the dispatched engine
    entry (`query`) — timed on the real serving path, cache attached and
    cold (unique patterns, so every call is a miss + insert) — must be no
    worse than the seed scalar worklist; the forced frontier-of-one
    documents the gap the dispatch closes."""

    def _cold_dispatched_us(pattern: str, nq: int) -> float:
        if itr.cache is None:  # cache-less engine: query() IS the worklist
            return time_queries(itr, ds, pattern, nq)[0]
        rows = np.unique(sample_rows(ds, 2 * nq), axis=0)[:nq]  # no repeats:
        itr.cache.clear()                                       # all misses
        t0 = time.perf_counter()
        for s, p, o in rows:
            itr.query(int(s) if pattern[0] == "s" else None,
                      int(p) if pattern[1] == "p" else None,
                      int(o) if pattern[2] == "o" else None)
        return (time.perf_counter() - t0) / len(rows) * 1e6

    out = {}
    for pattern in DISPATCH_PATTERNS:
        nq = min(n_queries, QUERIES_PER_PATTERN.get(pattern, n_queries), 100)
        # min over reps: single-run wall timings jitter more than the
        # dispatch overhead being measured
        dispatched_us = min(_cold_dispatched_us(pattern, nq) for _ in range(2))
        scalar_us = min(time_queries(itr, ds, pattern, nq,
                                     query_fn=itr.query_scalar)[0] for _ in range(2))
        crossover = itr.crossover
        itr.crossover = 0  # force the frontier path (time_queries detaches the cache)
        try:
            frontier_us, _ = time_queries(itr, ds, pattern, nq)
        finally:
            itr.crossover = crossover
        out[pattern] = {
            "dispatched_us": dispatched_us,
            "scalar_us": scalar_us,
            "frontier_single_us": frontier_us,
            "dispatched_vs_scalar": dispatched_us / scalar_us if scalar_us > 0 else float("inf"),
        }
        if not quiet:
            print(f"dispatch {pattern} dispatched={dispatched_us:9.1f}us "
                  f"scalar={scalar_us:9.1f}us frontier1={frontier_us:9.1f}us")
    bench["crossover_dispatch"] = {"crossover_width": itr.crossover, "patterns": out}


def _finalize_throughput(bench: dict, n_queries: int) -> None:
    """Aggregate qps = total batched queries / total batched wall time."""
    total_q = 0
    total_s = 0.0
    for pat, p in bench["patterns"].items():
        nq = min(n_queries, BATCH_QUERIES_PER_PATTERN.get(pat, n_queries))
        total_q += nq
        total_s += p["batched_us"] * nq / 1e6
    bench["batch_throughput_qps"] = total_q / total_s if total_s > 0 else 0.0


if __name__ == "__main__":
    run()
