"""Paper Figure 4: average runtime of 500 queries per triple pattern on the
geo-coordinates-en stand-in, per engine (ITR vs k²-triples vs HDT-BT).

The paper's claim under test: ITR answers every pattern except ?P? faster
than (or comparable to) the baselines, in milliseconds.

Beyond the paper: the batched engine (`query_batch_arrays`, one
level-synchronous frontier for the whole workload) is timed against the
seed per-query worklist (`query_scalar`) on the same workload, and the
results land in `BENCH_query_latency.json` — per-pattern µs, speedups, and
an aggregate `batch_throughput_qps` — so the serving-perf trajectory is
tracked from PR 1 onward.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import (
    BATCH_QUERIES_PER_PATTERN,
    PATTERNS,
    QUERIES_PER_PATTERN,
    build_all,
    time_queries,
    time_query_batch,
)
from repro.data.synthetic import PAPER_DATASETS


def run(dataset="geo-coordinates-en", n_queries=500, quiet=False,
        json_path="BENCH_query_latency.json"):
    ds = PAPER_DATASETS[dataset]()
    built = build_all(ds)
    built.pop("raw_bytes")
    itr = built["ITR"]["engine"]
    rows = []
    bench = {"dataset": dataset, "n_queries": n_queries, "patterns": {}}
    for pattern in PATTERNS:
        row = {"pattern": pattern}
        checks = {}
        for method, b in built.items():
            us, n_res = time_queries(b["engine"], ds, pattern, n_queries)
            row[method] = us
            checks[method] = n_res
        # seed per-query reference path (pre-batching worklist)
        scalar_us, scalar_n = time_queries(
            itr, ds, pattern, n_queries, query_fn=itr.query_scalar)
        checks["ITR-scalar"] = scalar_n
        # batched throughput on the full workload
        bat_us, bat_n, qps = time_query_batch(itr, ds, pattern, n_queries)
        # batched parity on the same capped sample as the per-query engines
        # (the timing run above already IS that sample unless caps differ)
        n_par = min(n_queries, QUERIES_PER_PATTERN.get(pattern, n_queries))
        n_bat = min(n_queries, BATCH_QUERIES_PER_PATTERN.get(pattern, n_queries))
        if n_par == n_bat:
            checks["ITR-batched"] = bat_n
        else:
            _, par_n, _ = time_query_batch(itr, ds, pattern, n_par)
            checks["ITR-batched"] = par_n
        # engines must agree on result counts (correctness guard)
        assert len(set(checks.values())) == 1, f"{pattern}: result mismatch {checks}"
        row["ITR-batched"] = bat_us
        speedup = scalar_us / bat_us if bat_us > 0 else float("inf")
        bench["patterns"][pattern] = {
            "scalar_us": scalar_us,
            "batched_us": bat_us,
            "speedup_vs_scalar": speedup,
            "batch_qps": qps,
            "n_results_batched": bat_n,
            "baseline_us": {m: row[m] for m in built},
        }
        rows.append(row)
        if not quiet:
            times = " ".join(f"{m}={row[m]:9.1f}us" for m in built)
            print(f"fig4 {pattern} {times} batched={bat_us:9.1f}us "
                  f"({speedup:5.1f}x vs scalar)  (n={checks['ITR']})")
    _finalize_throughput(bench, n_queries)
    Path(json_path).write_text(json.dumps(bench, indent=2))
    if not quiet:
        print(f"batch_throughput_qps={bench['batch_throughput_qps']:.0f} -> {json_path}")
    return rows


def _finalize_throughput(bench: dict, n_queries: int) -> None:
    """Aggregate qps = total batched queries / total batched wall time."""
    total_q = 0
    total_s = 0.0
    for pat, p in bench["patterns"].items():
        nq = min(n_queries, BATCH_QUERIES_PER_PATTERN.get(pat, n_queries))
        total_q += nq
        total_s += p["batched_us"] * nq / 1e6
    bench["batch_throughput_qps"] = total_q / total_s if total_s > 0 else 0.0


if __name__ == "__main__":
    run()
