"""Shared benchmark plumbing: build all compressors over a dataset, timed
query runner (paper: 500 queries per pattern, average ms)."""
from __future__ import annotations

import time
from contextlib import contextmanager

import numpy as np

from repro.baselines import HDTBitmapTriples, K2Triples, ntriples_size_bytes
from repro.core import (
    Hypergraph,
    LabelTable,
    TripleQueryEngine,
    attach_node_labels,
    compress,
    encode,
)

PATTERNS = ["s??", "?p?", "??o", "sp?", "s?o", "?po", "spo", "???"]


def build_itr(ds, plus=False, config=None):
    table = LabelTable.terminals([2] * ds.n_preds)
    graph = Hypergraph.from_triples(ds.triples, ds.n_nodes)
    extra = 0
    if plus and ds.node_labels is not None:
        n_kinds = int(ds.node_labels.max()) + 1
        graph, table, _base = attach_node_labels(graph, table, ds.node_labels)
        extra = n_kinds
    grammar, stats = compress(graph, table, config)
    enc = encode(grammar)
    engine = TripleQueryEngine(grammar, enc)
    return {"grammar": grammar, "encoded": enc, "engine": engine, "stats": stats,
            "size": enc.size_in_bytes()}


def build_all(ds, itr_config=None):
    out = {"ITR": build_itr(ds, plus=False, config=itr_config)}
    if ds.node_labels is not None:
        out["ITR+"] = build_itr(ds, plus=True, config=itr_config)
    out["k2-triples"] = {"engine": K2Triples(ds.triples, ds.n_nodes, ds.n_preds)}
    out["k2-triples"]["size"] = out["k2-triples"]["engine"].size_in_bytes()
    out["HDT-BT"] = {"engine": HDTBitmapTriples(ds.triples, ds.n_nodes, ds.n_preds)}
    out["HDT-BT"]["size"] = out["HDT-BT"]["engine"].size_in_bytes()
    out["raw_bytes"] = ntriples_size_bytes(ds.triples)
    return out


def _bind(pattern, s, p, o):
    return (s if pattern[0] == "s" else None,
            p if pattern[1] == "p" else None,
            o if pattern[2] == "o" else None)


# paper protocol is 500 queries/pattern (in C); the unselective patterns
# enumerate the whole graph per query, so at Python speed we sample fewer
# and still report per-query averages
QUERIES_PER_PATTERN = {"???": 5, "?p?": 50, "?po": 100, "??o": 100}

# batched execution amortizes per-query overhead, so the batch path runs the
# full 500 everywhere except ???, which materializes the entire decompressed
# graph per query (result volume, not engine speed, is the bound there)
BATCH_QUERIES_PER_PATTERN = {"???": 50}


@contextmanager
def engine_cache_disabled(engine):
    """Temporarily detach a TripleQueryEngine's cross-request result cache
    (no-op for baseline engines without one) so a timing loop measures the
    execution path rather than cache hits on repeated patterns."""
    cache = getattr(engine, "cache", None)
    if cache is None:
        yield
        return
    engine.cache = None
    try:
        yield
    finally:
        engine.cache = cache


def sample_rows(ds, n: int, seed: int = 0) -> np.ndarray:
    """The shared workload protocol: n triples drawn with replacement."""
    rng = np.random.default_rng(seed)
    return ds.triples[rng.integers(0, len(ds.triples), n)]


def bind_pattern(pattern: str, rows) -> tuple[list, list, list]:
    """Rows -> aligned s/p/o columns with None where the pattern is unbound."""
    bound = [_bind(pattern, int(s), int(p), int(o)) for s, p, o in rows]
    s_arr, p_arr, o_arr = (list(col) for col in zip(*bound))
    return s_arr, p_arr, o_arr


def time_queries(engine, ds, pattern: str, n_queries: int = 500, seed: int = 0,
                 query_fn=None):
    """Average µs per query (paper Figure 4 protocol: 500 random queries).

    `query_fn` overrides the per-query callable (default `engine.query`) —
    e.g. `engine.query_scalar` to time the pre-batching reference path.
    The engine's result cache is detached for the duration so duplicate
    sampled rows don't turn the latency column into a cache benchmark.
    """
    n_queries = min(n_queries, QUERIES_PER_PATTERN.get(pattern, n_queries))
    query = query_fn if query_fn is not None else engine.query
    rows = sample_rows(ds, n_queries, seed)
    with engine_cache_disabled(engine):
        t0 = time.perf_counter()
        n_results = 0
        for s, p, o in rows:
            qs, qp, qo = _bind(pattern, int(s), int(p), int(o))
            n_results += len(query(qs, qp, qo))
        dt = time.perf_counter() - t0
    return dt / n_queries * 1e6, n_results


def time_query_batch(engine, ds, pattern: str, n_queries: int = 500, seed: int = 0):
    """One `query_batch_arrays` call for the whole workload (array-native
    serving path, cross-request cache detached — the uncached baseline the
    warm-cache section is measured against).
    Returns (µs per query, n_results, queries/second)."""
    n_queries = min(n_queries, BATCH_QUERIES_PER_PATTERN.get(pattern, n_queries))
    s_arr, p_arr, o_arr = bind_pattern(pattern, sample_rows(ds, n_queries, seed))
    with engine_cache_disabled(engine):
        t0 = time.perf_counter()
        r_q, r_l, _, _ = engine.query_batch_arrays(s_arr, p_arr, o_arr)
        dt = time.perf_counter() - t0
    return dt / n_queries * 1e6, int(len(r_l)), n_queries / dt if dt > 0 else 0.0
