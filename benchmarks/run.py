"""Benchmark harness entry point — one section per paper table/figure.

Prints `name,value,derived` CSV lines per benchmark so results are grep-able
(`python -m benchmarks.run > bench_output.txt`).

`--smoke` runs every section on tiny inputs with one repetition and never
overwrites the tracked BENCH_*.json artifacts — it exists so CI can prove
the harness still executes end to end without paying full benchmark time.

`--smoke --check` is the CI benchmark-regression gate: the smoke run's
*dimensionless* metrics (speedups, dispatch ratios — absolute µs vary too
much across machines to gate on) are compared against the `smoke_baseline`
section committed in BENCH_query_latency.json, with a generous tolerance
(default 3x, `--tolerance`) so timing noise never fails a build but a real
regression — a speedup collapsing, dispatch suddenly slower than scalar —
does. The smoke metrics are written to BENCH_smoke_query_latency.json for
upload as a workflow artifact. `--smoke --update-baseline` re-records the
committed baseline from the current machine.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE_JSON = "BENCH_query_latency.json"
SMOKE_JSON = "BENCH_smoke_query_latency.json"
GATE_TOLERANCE = 3.0

# metric name suffixes where LOWER is better (ratios of our-time / reference)
_LOWER_IS_BETTER = ("dispatched_vs_scalar", "sharded_vs_single",
                    "overhead_vs_clean", "skew_after_vs_before",
                    "dict_vs_plain_bytes")


def gate_metrics(bench: dict) -> dict[str, float]:
    """Flatten a query-latency bench dict to the dimensionless metrics the
    regression gate compares. Only ratio-style numbers qualify: absolute
    latencies depend on the machine, ratios mostly cancel it out."""
    out: dict[str, float] = {}
    for pat, p in bench.get("patterns", {}).items():
        if pat == "???":
            # full-decompression pattern: capped at 5 scalar queries and
            # bounded by result-materialization volume, not engine speed —
            # too few samples to gate on without flakiness
            continue
        out[f"patterns.{pat}.speedup_vs_scalar"] = p["speedup_vs_scalar"]
    wc = bench.get("warm_cache", {})
    for pat, p in wc.get("patterns", {}).items():
        out[f"warm_cache.{pat}.warm_speedup_vs_uncached"] = \
            p["warm_speedup_vs_uncached"]
    if "point_lookup" in wc:
        out["warm_cache.point_lookup.warm_speedup"] = \
            wc["point_lookup"]["warm_speedup"]
    for pat, p in bench.get("crossover_dispatch", {}).get("patterns", {}).items():
        out[f"crossover_dispatch.{pat}.dispatched_vs_scalar"] = \
            p["dispatched_vs_scalar"]
    sharded = bench.get("sharded", {})
    if "warm_view" in sharded:
        out["sharded.warm_view.speedup_vs_materialized"] = \
            sharded["warm_view"]["speedup_vs_materialized"]
    for pat, p in sharded.get("scatter_gather", {}).items():
        out[f"sharded.scatter_gather.{pat}.sharded_vs_single"] = \
            p["sharded_vs_single"]
    mutation = bench.get("mutation", {})
    for tier, t in mutation.get("overlay", {}).get("tiers", {}).items():
        out[f"mutation.overlay.{tier}.overhead_vs_clean"] = \
            t["overhead_vs_clean"]
    if "rebuild" in mutation:
        out["mutation.rebuild.full_vs_incremental"] = \
            mutation["rebuild"]["full_vs_incremental"]
    rebalance = bench.get("rebalance", {})
    if rebalance:
        # deterministic balance gain of the online re-cut (lower = better)
        out["rebalance.skew_after_vs_before"] = \
            rebalance["skew_after_vs_before"]
        # migration must stay cheaper than a full re-partition
        out["rebalance.full_vs_migration"] = rebalance["full_vs_migration"]
    bgp = bench.get("bgp", {})
    if "chain3" in bgp:
        # the planned id-array join must keep beating the naive
        # per-pattern-then-Python-join baseline on a 3-pattern chain
        out["bgp.chain3.planned_vs_naive"] = bgp["chain3"]["planned_vs_naive"]
        # whole-BGP cache hits must keep short-circuiting repeat queries
        out["bgp.chain3.warm_speedup"] = bgp["chain3"]["warm_speedup"]
    recovery = bench.get("recovery", {})
    if "cold_start_speedup" in recovery:
        # snapshot cold start must stay cheaper than a RePair rebuild
        out["recovery.cold_start_speedup"] = recovery["cold_start_speedup"]
    ingestion = bench.get("ingestion", {})
    if "dict_vs_plain_bytes" in ingestion:
        # the front-coded term dictionary must stay smaller than a plain
        # forward+reverse Python mapping; size ratio is deterministic for
        # a given dataset, so it gates tightly despite the 3x tolerance
        out["ingestion.dict_vs_plain_bytes"] = \
            ingestion["dict_vs_plain_bytes"]
    load = bench.get("serving_load", {}).get("smoke_signals", {})
    if "achieved_vs_offered" in load:
        # open-loop throughput ratio at a sub-saturation offered rate:
        # collapses when the concurrent request plane stops keeping up
        out["serving_load.achieved_vs_offered"] = load["achieved_vs_offered"]
    if "scatter_fanout_speedup" in load:
        # threaded vs sequential scatter fan-out (~1.0 on 1-core runners)
        out["serving_load.scatter_fanout_speedup"] = \
            load["scatter_fanout_speedup"]
    if "replica_scaling_speedup" in load:
        # read QPS at max replica groups vs one (~1.0 on 1-core runners):
        # collapses when replica dispatch breaks or stops spreading load
        out["serving_load.replica_scaling_speedup"] = \
            load["replica_scaling_speedup"]
    return {k: float(v) for k, v in out.items()}


def _load_bench_json(path: str, remedy: str) -> dict | None:
    """Read one bench JSON artifact; on any failure print an actionable
    `gate ERROR` (what is wrong + how to fix it) and return None."""
    try:
        doc = json.loads(Path(path).read_text())
    except FileNotFoundError:
        print(f"gate ERROR: {path} not found — {remedy}", file=sys.stderr)
        return None
    except json.JSONDecodeError as exc:
        print(f"gate ERROR: {path} is not valid JSON ({exc}) — {remedy}",
              file=sys.stderr)
        return None
    if not isinstance(doc, dict):
        print(f"gate ERROR: {path} must hold a JSON object, got "
              f"{type(doc).__name__} — {remedy}", file=sys.stderr)
        return None
    return doc


def check_regressions(smoke_path: str = SMOKE_JSON,
                      baseline_path: str = BASELINE_JSON,
                      tolerance: float | None = None) -> int:
    """Compare smoke gate metrics against the committed smoke baseline.

    Metrics only on the smoke side are skipped (new metrics don't fail
    the gate until a baseline is recorded for them), but a metric the
    BASELINE has and the smoke run no longer emits is a FAILURE — a
    renamed/dropped section silently losing its gates is exactly the
    coverage loss this gate exists to catch. `tolerance` defaults to the
    one recorded alongside the baseline (so re-recording with
    `--update-baseline --tolerance N` actually changes the gate).
    Returns the number of regressions; prints one `gate ...` line each.
    Every malformed-input path (missing file, invalid JSON, missing
    `smoke_baseline` section, a section metric that lost its value)
    fails with an actionable `gate ERROR` line instead of a traceback.
    """
    smoke_doc = _load_bench_json(
        smoke_path, "re-run `python -m benchmarks.run --smoke --check` "
        "(the smoke run writes it)")
    baseline_doc = _load_bench_json(
        baseline_path, "restore the tracked artifact or re-record it with "
        "`python -m benchmarks.run` then `--smoke --update-baseline`")
    if smoke_doc is None or baseline_doc is None:
        return 1
    try:
        smoke = gate_metrics(smoke_doc)
    except (KeyError, TypeError) as exc:
        print(f"gate ERROR: {smoke_path} has a bench section missing its "
              f"expected metric ({exc!r}); the smoke run and the gate "
              f"disagree about the schema — re-run "
              f"`python -m benchmarks.run --smoke --check` from this "
              f"checkout", file=sys.stderr)
        return 1
    section = baseline_doc.get("smoke_baseline")
    if not isinstance(section, dict):
        print(f"gate ERROR: no smoke_baseline section in {baseline_path}; "
              f"record one with "
              f"`python -m benchmarks.run --smoke --update-baseline`",
              file=sys.stderr)
        return 1
    if tolerance is None:
        tolerance = float(section.get("tolerance", GATE_TOLERANCE))
    base = section.get("metrics")
    if not isinstance(base, dict) or not base:
        print(f"gate ERROR: smoke_baseline in {baseline_path} has no "
              f"metrics mapping; re-record it with "
              f"`python -m benchmarks.run --smoke --update-baseline`",
              file=sys.stderr)
        return 1
    bad = {k: v for k, v in base.items()
           if not isinstance(v, (int, float)) or isinstance(v, bool)}
    if bad:
        print(f"gate ERROR: smoke_baseline metrics in {baseline_path} "
              f"must be numbers; offending entries: "
              f"{', '.join(sorted(bad))} — re-record with "
              f"`python -m benchmarks.run --smoke --update-baseline`",
              file=sys.stderr)
        return 1
    failures = 0
    for name in sorted(set(smoke) & set(base)):
        got, want = smoke[name], base[name]
        if name.endswith(_LOWER_IS_BETTER):
            ok = got <= want * tolerance
            bound = f"<= {want * tolerance:.2f}"
        else:
            ok = got >= want / tolerance
            bound = f">= {want / tolerance:.2f}"
        failures += not ok
        print(f"gate {name}: smoke={got:.2f} baseline={want:.2f} "
              f"({bound}) {'PASS' if ok else 'FAIL'}")
    for name in sorted(set(base) - set(smoke)):
        failures += 1
        print(f"gate {name}: MISSING from smoke run (baseline gates it) FAIL")
    fresh = sorted(set(smoke) - set(base))
    if fresh:
        print(f"gate # {len(fresh)} new metric(s) skipped until a baseline "
              f"is recorded: {', '.join(fresh)}")
    print(f"gate summary: {failures} regression(s) at {tolerance:g}x tolerance")
    return failures


def conservative_envelope(metric_dicts: list[dict]) -> dict[str, float]:
    """Fold several runs' gate metrics into one baseline, taking each
    metric's WORST observed side (min for higher-is-better, max for
    lower-is-better). Gating against the envelope means the tolerance
    band absorbs run-to-run timing noise instead of flagging it — only a
    regression beyond (worst observed) / tolerance fails."""
    out: dict[str, float] = {}
    for m in metric_dicts:
        for k, v in m.items():
            if k not in out:
                out[k] = v
            elif k.endswith(_LOWER_IS_BETTER):
                out[k] = max(out[k], v)
            else:
                out[k] = min(out[k], v)
    return out


def update_baseline_from(bench_dicts: list[dict],
                         baseline_path: str = BASELINE_JSON,
                         tolerance: float | None = None) -> None:
    """Record the conservative envelope of smoke bench dicts as the
    committed gate baseline (with the tolerance future `--check` runs
    will gate at). Refreshing without --tolerance keeps any previously
    recorded custom tolerance."""
    doc = json.loads(Path(baseline_path).read_text())
    if tolerance is None:
        tolerance = doc.get("smoke_baseline", {}).get("tolerance", GATE_TOLERANCE)
    doc["smoke_baseline"] = {
        "tolerance": float(tolerance),
        "runs": len(bench_dicts),
        "note": "conservative envelope of dimensionless smoke metrics for "
                "`benchmarks.run --smoke --check`; refresh with "
                "--smoke --update-baseline",
        "metrics": conservative_envelope([gate_metrics(b) for b in bench_dicts]),
    }
    Path(baseline_path).write_text(json.dumps(doc, indent=2))
    print(f"smoke_baseline updated in {baseline_path} "
          f"({len(bench_dicts)} run(s), tolerance {tolerance:g}x)")


def update_baseline(smoke_path: str = SMOKE_JSON,
                    baseline_path: str = BASELINE_JSON,
                    tolerance: float | None = None) -> None:
    """Single-run convenience wrapper around :func:`update_baseline_from`."""
    update_baseline_from([json.loads(Path(smoke_path).read_text())],
                         baseline_path, tolerance)


def main(smoke: bool = False, check: bool = False,
         update: bool = False, tolerance: float | None = None) -> None:
    from benchmarks import (
        compression_ratio,
        compression_speed,
        itr_plus_bench,
        kernels_bench,
        query_latency,
        serving_load,
    )

    def _merge_serving_load(quiet: bool = True) -> dict:
        """Run the load-harness smoke pass and fold it into the smoke
        artifact, so the gate sees its dimensionless signals alongside the
        query-latency ones."""
        load = serving_load.run_smoke(quiet=quiet)
        doc = json.loads(Path(SMOKE_JSON).read_text())
        doc["serving_load"] = load
        Path(SMOKE_JSON).write_text(json.dumps(doc, indent=2))
        return doc

    print("== Table 1b / Figure 3: compression ratio per dataset ==")
    fig3 = compression_ratio.run(datasets=["ttt-win"] if smoke else compression_ratio.DATASETS)
    print("\n== Figure 4: triple-query latency (500 queries/pattern) ==")
    if smoke:
        # the gate needs the smoke bench dict on disk; plain smoke runs
        # stay write-free (BENCH_*.json artifacts are never overwritten)
        smoke_json = SMOKE_JSON if (check or update) else None
        fig4 = query_latency.run(n_queries=25, scale=0.02, json_path=smoke_json)
        print("\n== serving load (open-loop smoke) ==")
        if smoke_json:
            _merge_serving_load(quiet=False)
        else:
            serving_load.run_smoke(quiet=False)
    else:
        fig4 = query_latency.run()
        print("\n== serving load (open-loop) ==")
        load_bench = serving_load.run()
    print("\n== §ITR+: node-label hyperedges (ttt-win) ==")
    plus = itr_plus_bench.run()
    print("\n== ablations: §Handling loops + mfd selection ==")
    from benchmarks import ablations

    abl = ablations.run()
    print("\n== compression throughput ==")
    speed = compression_speed.run(sizes=(2000,) if smoke else (2000, 8000, 32000))
    print("\n== kernel micro-bench (CPU interpret) ==")
    kerns = kernels_bench.run()

    print("\n== CSV ==")
    print("name,value,derived")
    for row in fig3:
        for m in ("ITR", "ITR+", "k2-triples", "HDT-BT"):
            if m in row:
                print(f"fig3/{row['dataset']}/{m},{row[m]:.6f},ratio")
    for row in fig4:
        for m, v in row.items():
            if m != "pattern":
                print(f"fig4/{row['pattern']}/{m},{v:.1f},us_per_query")
    # batched-engine trajectory (written by query_latency.run; in smoke mode
    # the tracked file is not rewritten, so skip rather than report stale)
    if not smoke:
        try:
            bench = json.loads(Path(BASELINE_JSON).read_text())
            print(f"fig4/batch_throughput_qps,{bench['batch_throughput_qps']:.0f},qps")
            for pat, p in bench["patterns"].items():
                print(f"fig4/{pat}/speedup_vs_scalar,{p['speedup_vs_scalar']:.2f},x")
            for pat, p in bench.get("warm_cache", {}).get("patterns", {}).items():
                print(f"fig4/{pat}/warm_speedup_vs_uncached,{p['warm_speedup_vs_uncached']:.2f},x")
            for pat, p in bench.get("crossover_dispatch", {}).get("patterns", {}).items():
                print(f"fig4/{pat}/dispatched_vs_scalar,{p['dispatched_vs_scalar']:.2f},x")
            sharded = bench.get("sharded", {})
            for strat, per in sharded.get("strategies", {}).items():
                for n_shards, v in per.items():
                    print(f"sharded/{strat}/P{n_shards}/warm_qps,{v['warm_qps']:.0f},qps")
            if "warm_view" in sharded:
                print(f"sharded/warm_view/speedup_vs_materialized,"
                      f"{sharded['warm_view']['speedup_vs_materialized']:.2f},x")
            mutation = bench.get("mutation", {})
            for tier, t in mutation.get("overlay", {}).get("tiers", {}).items():
                print(f"mutation/overlay/{tier}/overhead_vs_clean,"
                      f"{t['overhead_vs_clean']:.2f},x")
            if "rebuild" in mutation:
                print(f"mutation/rebuild/full_vs_incremental,"
                      f"{mutation['rebuild']['full_vs_incremental']:.2f},x")
            rebalance = bench.get("rebalance", {})
            if rebalance:
                print(f"rebalance/skew_after_vs_before,"
                      f"{rebalance['skew_after_vs_before']:.3f},x")
                print(f"rebalance/full_vs_migration,"
                      f"{rebalance['full_vs_migration']:.2f},x")
                print(f"rebalance/migrated_rows,"
                      f"{rebalance['migrated_rows']},rows")
            recovery = bench.get("recovery", {})
            if recovery:
                print(f"recovery/cold_start_speedup,"
                      f"{recovery['cold_start_speedup']:.2f},x")
                print(f"recovery/wal_replay_records_per_s,"
                      f"{recovery['wal_replay_records_per_s']:.0f},rec_per_s")
                print(f"recovery/first_query_after_open_us,"
                      f"{recovery['first_query_after_open_us']:.1f},us")
            ingestion = bench.get("ingestion", {})
            if ingestion:
                print(f"ingestion/dict_vs_plain_bytes,"
                      f"{ingestion['dict_vs_plain_bytes']:.4f},ratio")
                print(f"ingestion/terms_per_s,"
                      f"{ingestion['terms_per_s']:.0f},terms_per_s")
                print(f"ingestion/rows_per_s,"
                      f"{ingestion['rows_per_s']:.0f},rows_per_s")
                print(f"ingestion/dict_bytes_per_term,"
                      f"{ingestion['dict_bytes_per_term']:.2f},bytes")
        except Exception as e:
            print(f"# {BASELINE_JSON} unavailable: {e}", file=sys.stderr)
        lat = load_bench.get("latency", {})
        for q in ("p50_ms", "p95_ms", "p99_ms"):
            print(f"serving_load/{q},{lat.get(q, 0.0):.3f},ms")
        print(f"serving_load/saturation_qps,"
              f"{load_bench['saturation']['saturation_qps']:.0f},qps")
        print(f"serving_load/scatter_fanout_speedup,"
              f"{load_bench['scatter_fanout']['speedup']:.2f},x")
        print(f"serving_load/replica_scaling_speedup,"
              f"{load_bench['replica_scaling']['speedup']:.2f},x")
    p = plus[0]
    print(f"itr_plus/ttt-win/gain,{p['plus_gain']:.4f},fraction")
    for row in abl["loop_rules"]:
        print(f"ablation/loop_rules/{row['dataset']},{row['loop_rule_bytes']/row['index_fn_bytes']:.4f},vs_index_fn")
    for row in abl["selection"]:
        print(f"ablation/selection/{row['dataset']},{row['savings_gain']:.4f},savings_vs_count")
    for row in speed:
        print(f"speed/E{row['edges']},{row['edges_per_s']:.0f},edges_per_s")
    for row in kerns:
        print(f"kernel/{row['kernel']},{row['pallas_interpret_us']:.1f},us_per_call")

    # roofline summary if the dry-run has produced results (skipped in smoke:
    # it only reports on artifacts a TPU dry-run would have left behind)
    if not smoke:
        try:
            from benchmarks import roofline_report

            rows = roofline_report.run(quiet=True)
            ok = [r for r in rows if r.get("ok")]
            if ok:
                print(f"roofline/cells_ok,{len(ok)},count")
                for r in ok:
                    print(f"roofline/{r['arch']}/{r['shape']}/dominant,{r['dominant']},bottleneck")
        except Exception as e:  # dry-run not yet executed
            print(f"# roofline skipped: {e}", file=sys.stderr)

    if smoke and update:
        print("\n== gate baseline ==")
        # envelope over extra latency-section runs: smoke ratios jitter by
        # ~2-3x run to run, so a single-shot baseline plus 3x tolerance
        # would flag noise; the worst observed side per metric won't
        runs = [json.loads(Path(SMOKE_JSON).read_text())]
        for _ in range(2):
            # query_latency.run rewrites SMOKE_JSON from scratch, so the
            # serving_load section must be re-run and re-merged per pass
            query_latency.run(n_queries=25, scale=0.02, json_path=SMOKE_JSON,
                              quiet=True)
            runs.append(_merge_serving_load())
        update_baseline_from(runs, tolerance=tolerance)
    if smoke and check:
        print("\n== benchmark-regression gate ==")
        if check_regressions(tolerance=tolerance):
            sys.exit(1)


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny graphs, 1 repetition, no tracked-JSON overwrite")
    parser.add_argument("--check", action="store_true",
                        help="with --smoke: fail on regression vs the committed "
                             "smoke_baseline (writes BENCH_smoke_query_latency.json)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="with --smoke: re-record the committed smoke_baseline")
    parser.add_argument("--tolerance", type=float, default=None,
                        help="gate tolerance factor (default: the one recorded "
                             f"in the baseline, else {GATE_TOLERANCE:g})")
    args = parser.parse_args()
    if (args.check or args.update_baseline) and not args.smoke:
        parser.error("--check/--update-baseline require --smoke")
    main(smoke=args.smoke, check=args.check, update=args.update_baseline,
         tolerance=args.tolerance)
