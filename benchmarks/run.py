"""Benchmark harness entry point — one section per paper table/figure.

Prints `name,value,derived` CSV lines per benchmark so results are grep-able
(`python -m benchmarks.run > bench_output.txt`).

`--smoke` runs every section on tiny inputs with one repetition and never
overwrites the tracked BENCH_*.json artifacts — it exists so CI can prove
the harness still executes end to end without paying full benchmark time.
"""
from __future__ import annotations

import argparse
import sys


def main(smoke: bool = False) -> None:
    from benchmarks import (
        compression_ratio,
        compression_speed,
        itr_plus_bench,
        kernels_bench,
        query_latency,
    )

    print("== Table 1b / Figure 3: compression ratio per dataset ==")
    fig3 = compression_ratio.run(datasets=["ttt-win"] if smoke else compression_ratio.DATASETS)
    print("\n== Figure 4: triple-query latency (500 queries/pattern) ==")
    if smoke:
        fig4 = query_latency.run(n_queries=25, scale=0.02, json_path=None)
    else:
        fig4 = query_latency.run()
    print("\n== §ITR+: node-label hyperedges (ttt-win) ==")
    plus = itr_plus_bench.run()
    print("\n== ablations: §Handling loops + mfd selection ==")
    from benchmarks import ablations

    abl = ablations.run()
    print("\n== compression throughput ==")
    speed = compression_speed.run(sizes=(2000,) if smoke else (2000, 8000, 32000))
    print("\n== kernel micro-bench (CPU interpret) ==")
    kerns = kernels_bench.run()

    print("\n== CSV ==")
    print("name,value,derived")
    for row in fig3:
        for m in ("ITR", "ITR+", "k2-triples", "HDT-BT"):
            if m in row:
                print(f"fig3/{row['dataset']}/{m},{row[m]:.6f},ratio")
    for row in fig4:
        for m, v in row.items():
            if m != "pattern":
                print(f"fig4/{row['pattern']}/{m},{v:.1f},us_per_query")
    # batched-engine trajectory (written by query_latency.run; in smoke mode
    # the file is not rewritten, so skip rather than report stale numbers)
    if not smoke:
        try:
            import json

            bench = json.loads(open("BENCH_query_latency.json").read())
            print(f"fig4/batch_throughput_qps,{bench['batch_throughput_qps']:.0f},qps")
            for pat, p in bench["patterns"].items():
                print(f"fig4/{pat}/speedup_vs_scalar,{p['speedup_vs_scalar']:.2f},x")
            for pat, p in bench.get("warm_cache", {}).get("patterns", {}).items():
                print(f"fig4/{pat}/warm_speedup_vs_uncached,{p['warm_speedup_vs_uncached']:.2f},x")
            for pat, p in bench.get("crossover_dispatch", {}).get("patterns", {}).items():
                print(f"fig4/{pat}/dispatched_vs_scalar,{p['dispatched_vs_scalar']:.2f},x")
        except Exception as e:
            print(f"# BENCH_query_latency.json unavailable: {e}", file=sys.stderr)
    p = plus[0]
    print(f"itr_plus/ttt-win/gain,{p['plus_gain']:.4f},fraction")
    for row in abl["loop_rules"]:
        print(f"ablation/loop_rules/{row['dataset']},{row['loop_rule_bytes']/row['index_fn_bytes']:.4f},vs_index_fn")
    for row in abl["selection"]:
        print(f"ablation/selection/{row['dataset']},{row['savings_gain']:.4f},savings_vs_count")
    for row in speed:
        print(f"speed/E{row['edges']},{row['edges_per_s']:.0f},edges_per_s")
    for row in kerns:
        print(f"kernel/{row['kernel']},{row['pallas_interpret_us']:.1f},us_per_call")

    # roofline summary if the dry-run has produced results (skipped in smoke:
    # it only reports on artifacts a TPU dry-run would have left behind)
    if not smoke:
        try:
            from benchmarks import roofline_report

            rows = roofline_report.run(quiet=True)
            ok = [r for r in rows if r.get("ok")]
            if ok:
                print(f"roofline/cells_ok,{len(ok)},count")
                for r in ok:
                    print(f"roofline/{r['arch']}/{r['shape']}/dominant,{r['dominant']},bottleneck")
        except Exception as e:  # dry-run not yet executed
            print(f"# roofline skipped: {e}", file=sys.stderr)


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny graphs, 1 repetition, no JSON overwrite")
    main(smoke=parser.parse_args().smoke)
