"""Kernel micro-bench: µs/call of each Pallas kernel (interpret on CPU —
informational; the TPU numbers come from the roofline dry-run) vs its jnp
reference."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.segment_matmul import build_csr_blocks


def _k2_batched_row_bench(rng, n_rows=256, iters=3):
    """Time one batched multi-row k²-tree expansion with the bitvector rank
    routed through the Pallas kernel (interpret off-TPU) vs pure numpy."""
    from repro.core.succinct import K2Tree, set_rank_backend

    n = m = 2048
    r = rng.integers(0, n, 20000)
    c = rng.integers(0, m, 20000)
    tree = K2Tree(r, c, n, m)
    qs = rng.integers(0, n, n_rows).astype(np.int64)

    def run_once():
        return tree.rows_many(qs)

    timings = {}
    for backend in ("pallas", "numpy"):
        old = set_rank_backend(backend)
        run_once()  # warmup (compilation / caches)
        t0 = time.perf_counter()
        for _ in range(iters):
            run_once()
        timings[backend] = (time.perf_counter() - t0) / iters * 1e6
        set_rank_backend(old)
    return (f"k2_rows_batched_{n_rows}r", timings["pallas"], timings["numpy"])


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quiet=False):
    rng = np.random.default_rng(0)
    rows = []

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    flash = lambda: ops.flash_attention(q, k, k, block_q=128, block_k=128)
    attn_ref = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
    rows.append(("flash_attention_256", _time(lambda *a: flash()),
                 _time(attn_ref, q, k, k)))

    x = jnp.asarray(rng.normal(size=(512, 128)), jnp.float32)
    s = rng.integers(0, 512, 2048)
    r = rng.integers(0, 512, 2048)
    src, dst = build_csr_blocks(s, r, 512)
    rows.append(("csr_spmm_2048e", _time(ops.csr_spmm, x, jnp.asarray(src), jnp.asarray(dst), 512),
                 _time(jax.jit(lambda x: ref.spmm_ref(x, jnp.asarray(s), jnp.asarray(r), 512)), x)))

    tbl = jnp.asarray(rng.normal(size=(5000, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 5000, (256, 4)), jnp.int32)
    rows.append(("embedding_bag_256x4", _time(ops.embedding_bag, tbl, idx),
                 _time(jax.jit(lambda t, i: ref.embedding_bag_ref(t, i)), tbl, idx)))

    xf = jnp.asarray(rng.normal(size=(128, 27, 128)), jnp.float32)
    rows.append(("dot_interaction_27f", _time(ops.dot_interaction, xf),
                 _time(jax.jit(ref.dot_interaction_ref), xf)))

    its = jnp.asarray(rng.integers(0, 50, (512, 8)), jnp.int32)
    cnts = jnp.asarray(rng.integers(1, 9, (512, 8)), jnp.int32)
    rows.append(("digram_count_512x8", _time(ops.digram_pair_counts, its, cnts),
                 _time(jax.jit(ref.digram_pair_counts_ref), its, cnts)))

    words = jnp.asarray(rng.integers(0, 2**32, 4096, dtype=np.uint64).astype(np.uint32))
    ranks = jnp.asarray(rng.integers(0, 100, 4096), jnp.int32)
    pos = jnp.asarray(rng.integers(0, 4096 * 32, 1024), jnp.int32)
    rows.append(("bitvec_rank_1024q", _time(ops.bitvec_rank, words, ranks, pos),
                 _time(jax.jit(ref.bitvec_rank_ref), words, ranks, pos)))

    # non-multiple-of-block batch: exercises the pad-to-boundary path
    pos_odd = jnp.asarray(rng.integers(0, 4096 * 32, 1000), jnp.int32)
    rows.append(("bitvec_rank_1000q_pad", _time(ops.bitvec_rank, words, ranks, pos_odd),
                 _time(jax.jit(ref.bitvec_rank_ref), words, ranks, pos_odd)))

    # batched k²-tree multi-row traversal (the query-engine hot loop): one
    # level-synchronous sweep for 256 rows, rank routed pallas vs numpy
    rows.append(_k2_batched_row_bench(rng, n_rows=256))

    out = []
    for name, k_us, r_us in rows:
        out.append({"kernel": name, "pallas_interpret_us": k_us, "jnp_ref_us": r_us})
        if not quiet:
            print(f"kern {name:<22} pallas(interp)={k_us:9.1f}us  jnp_ref={r_us:9.1f}us")
    return out


if __name__ == "__main__":
    run()
