"""Traffic-realistic serving load harness for the sharded tier.

Where `benchmarks.query_latency` measures closed-loop latency (one request
at a time, the next issued when the previous returns), this harness drives
the :class:`~repro.serve.sharded.ShardedTripleService` the way production
traffic actually arrives — **open loop**: requests are scheduled by a
Poisson process at a fixed *offered* rate whether or not the service has
kept up, so queueing delay is part of every latency sample instead of
being silently absorbed by the generator. Three sections land in
``BENCH_serving_load.json`` (schema: ``docs/BENCHMARKS.md``):

* ``latency`` — p50/p95/p99 at a sub-saturation offered rate, measured
  from each request's *scheduled arrival* to its completion, under a
  hot/cold pattern mix (a small hot set of repeated lookups over a cold
  random tail, plus occasional unselective ``?P?`` scans) with background
  mutation traffic running the whole time;
* ``saturation`` — a sweep over increasing offered rates; the saturation
  QPS is the highest rate the service still clears (achieved ≥ 90% of
  offered);
* ``scatter_fanout`` — the same unselective scatter workload executed
  sequentially (``serve_threads=1``) and threaded (one thread per core),
  whose ``speedup`` is the dimensionless signal the CI smoke gate tracks
  (on a single-core runner it sits at ~1.0 by construction);
* ``replica_scaling`` — closed-loop read QPS over a durable tier as the
  replica-group count grows 0 -> N (``enable_replication``, see
  docs/ARCHITECTURE.md §11): owned point lookups under concurrent
  clients, where extra replica groups dilute per-engine lock contention.
  ``replica_scaling_speedup`` (QPS at max replicas over QPS at one) is
  the smoke-gated signal; like the fan-out section it sits at ~1.0 on a
  single-core runner (``cpu_count`` is recorded alongside).

Knobs (flags override env, env overrides defaults): ``ITR_LOAD_DURATION``
(seconds per measured window), ``ITR_LOAD_RATES`` (comma-separated offered
QPS sweep), ``ITR_LOAD_CLIENTS`` (worker threads draining the arrival
queue), ``ITR_LOAD_HOT`` (hot-set fraction of the mix),
``ITR_LOAD_MUTATIONS`` (background mutation ops/second), ``ITR_LOAD_SEED``.

Run ``python -m benchmarks.serving_load --smoke`` for a seconds-long pass
on a tiny graph (no tracked JSON overwritten), or without ``--smoke`` to
refresh ``BENCH_serving_load.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import queue
import threading
import time
from pathlib import Path

import numpy as np

from repro.data.synthetic import PAPER_DATASETS
from repro.serve.concurrency import resolve_serve_threads
from repro.serve.sharded import ShardedTripleService

BENCH_JSON = "BENCH_serving_load.json"

HOT_SET_SIZE = 16


def _env_float(name: str, default: float) -> float:
    raw = os.environ.get(name, "").strip()
    try:
        return float(raw) if raw else default
    except ValueError:
        return default


def _env_rates(default: tuple) -> tuple:
    raw = os.environ.get("ITR_LOAD_RATES", "").strip()
    if not raw:
        return default
    try:
        rates = tuple(float(r) for r in raw.split(",") if r.strip())
        return rates or default
    except ValueError:
        return default


# ------------------------------------------------------------- workload
def _pattern_stream(triples: np.ndarray, rng, hot_frac: float, n: int) -> list:
    """The hot/cold request mix: `hot_frac` of requests re-look-up one of
    ``HOT_SET_SIZE`` hot rows (the dashboard/entity-page shape); the cold
    tail is point lookups and subject scans over random live rows, with a
    thin slice of unselective ``?P?`` scatter scans."""
    hot = triples[rng.integers(0, len(triples), HOT_SET_SIZE)]
    out = []
    for _ in range(n):
        if rng.random() < hot_frac:
            s, p, _ = hot[int(rng.integers(0, HOT_SET_SIZE))]
            out.append((int(s), int(p), None))
            continue
        s, p, o = triples[int(rng.integers(0, len(triples)))]
        r = rng.random()
        if r < 0.45:
            out.append((int(s), None, None))
        elif r < 0.85:
            out.append((int(s), int(p), int(o)))
        else:
            out.append((None, int(p), None))  # unselective: scatters
    return out


class _Mutator(threading.Thread):
    """Background write traffic: ~`rate` mutation calls/second, each
    inserting or deleting a few random rows (valid predicate ids, so the
    tier applies them for real)."""

    def __init__(self, svc, triples, n_nodes, n_preds, rate, stop, seed):
        super().__init__(name="load-mutator", daemon=True)
        self.svc, self.stop, self.rate = svc, stop, rate
        self.n_nodes, self.n_preds = n_nodes, n_preds
        self.triples = triples
        self.rng = np.random.default_rng(seed)
        self.ops = 0

    def run(self):
        while not self.stop.is_set() and self.rate > 0:
            k = int(self.rng.integers(1, 4))
            rows = np.stack([self.rng.integers(0, self.n_nodes, k),
                             self.rng.integers(0, self.n_preds, k),
                             self.rng.integers(0, self.n_nodes, k)], axis=1)
            if self.rng.integers(0, 2):
                self.svc.insert_triples(rows)
            else:
                self.svc.delete_triples(rows)
            self.ops += 1
            self.stop.wait(1.0 / self.rate)


def _open_loop(svc, requests: list, rate: float, clients: int, rng) -> dict:
    """Drive one measured window at offered `rate` QPS.

    Arrivals follow a Poisson process (exponential gaps); `clients`
    worker threads drain the arrival queue. Latency is measured from the
    request's SCHEDULED arrival, not its dequeue — when the service falls
    behind, queueing delay lands in the percentiles, which is the whole
    point of the open loop.
    """
    gaps = rng.exponential(1.0 / rate, len(requests))
    arrivals = np.cumsum(gaps)
    work: queue.Queue = queue.Queue()
    lats: list[float] = []
    lock = threading.Lock()
    t0 = time.perf_counter()

    def worker():
        while True:
            item = work.get()
            if item is None:
                return
            sched, (s, p, o) = item
            svc.query(s, p, o)
            done = time.perf_counter() - t0
            with lock:
                lats.append(done - sched)

    threads = [threading.Thread(target=worker, name=f"load-client-{i}")
               for i in range(clients)]
    for t in threads:
        t.start()
    for sched, req in zip(arrivals, requests):
        wait = sched - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        work.put((float(sched), req))
    for _ in threads:
        work.put(None)
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    lat = np.asarray(lats)
    return {
        "offered_qps": float(rate),
        "achieved_qps": float(len(lat) / wall) if wall > 0 else 0.0,
        "n_requests": int(len(lat)),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p95_ms": float(np.percentile(lat, 95) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "max_ms": float(lat.max() * 1e3),
    }


# -------------------------------------------------------- fan-out section
def _scatter_fanout(triples, n_nodes, n_preds, *, n_shards, reps,
                    threads: int, quiet: bool) -> dict:
    """Unselective scatter workload, sequential vs threaded fan-out.

    Cache disabled (a warm merged entry would answer without fanning out
    at all) and the same service instance re-timed under both widths, so
    the only variable is `serve_threads`.
    """
    svc = ShardedTripleService.build(
        triples, n_nodes, n_preds, n_shards=n_shards,
        strategy="predicate_hash", cache=None, rebalance_skew=None,
        serve_threads=1)
    patterns = [(None, p, None) for p in range(n_preds)] \
        + [(None, None, int(o)) for o in range(0, n_nodes, max(1, n_nodes // 8))]

    def measure() -> float:
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            svc.query_many(patterns)
            best = min(best, time.perf_counter() - t0)
        return best

    seq = measure()
    svc.set_serve_threads(threads)
    thr = measure()
    svc.close()
    out = {
        "threads": int(threads),
        "n_patterns": len(patterns),
        "sequential_s": seq,
        "threaded_s": thr,
        "speedup": seq / thr if thr > 0 else 0.0,
    }
    if not quiet:
        print(f"scatter fan-out x{threads}: sequential {seq * 1e3:.1f}ms, "
              f"threaded {thr * 1e3:.1f}ms -> {out['speedup']:.2f}x")
    return out


# -------------------------------------------------- replica scaling section
def _closed_loop_qps(svc, patterns: list, clients: int, reps: int) -> float:
    """Best-of-`reps` closed-loop QPS: `clients` threads each drain their
    slice of `patterns` flat out; QPS = total requests / wall."""
    chunks = [patterns[i::clients] for i in range(clients)]
    best = 0.0
    for _ in range(reps):
        start = threading.Barrier(clients + 1)

        def worker(chunk):
            start.wait()
            for s, p, o in chunk:
                svc.query(s, p, o)

        threads = [threading.Thread(target=worker, args=(c,)) for c in chunks]
        for t in threads:
            t.start()
        start.wait()
        t0 = time.perf_counter()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if wall > 0:
            best = max(best, len(patterns) / wall)
    return best


def _replica_scaling(triples, n_nodes, n_preds, *, n_shards, clients,
                     n_queries, reps, counts, quiet: bool) -> dict:
    """Read QPS vs replica-group count over one durable tier.

    Cache disabled (a warm entry would answer without touching any
    engine) and the workload is subject-bound ``sp?`` lookups — each
    flush routes to exactly one shard's engine, so with replicas off the
    clients contend on the primary's per-engine locks and each added
    group dilutes that contention. The tier is quiesced (lag 0, no
    mutations), isolating dispatch width as the only variable.
    """
    import tempfile

    from repro.persist.service import DurableShardedService

    rng = np.random.default_rng(7)
    rows = triples[rng.integers(0, len(triples), n_queries)]
    patterns = [(int(s), int(p), None) for s, p, _ in rows]
    qps: list[dict] = []
    with tempfile.TemporaryDirectory() as root:
        svc = DurableShardedService.build(
            triples, n_nodes, n_preds, root=os.path.join(root, "tier"),
            n_shards=n_shards, strategy="node_range", cache=None,
            rebalance_skew=None, serve_threads=1, fsync=False, replicas=0)
        try:
            for n in counts:
                svc.enable_replication(n)
                measured = _closed_loop_qps(svc, patterns, clients, reps)
                qps.append({"replicas": int(n), "qps": measured})
                if not quiet:
                    print(f"replica scaling x{n}: {measured:.0f} qps "
                          f"({clients} clients)")
        finally:
            svc.close()
    by_count = {w["replicas"]: w["qps"] for w in qps}
    base = by_count.get(1) or by_count[min(by_count)]
    top = by_count[max(by_count)]
    return {
        "cpu_count": os.cpu_count(),
        "clients": int(clients),
        "n_queries": len(patterns),
        "counts": [w["replicas"] for w in qps],
        "read_qps": [w["qps"] for w in qps],
        "speedup": top / base if base > 0 else 0.0,
    }


# ----------------------------------------------------------------- driver
def run(dataset: str = "geo-coordinates-en", *, scale=None,
        duration: float | None = None, rates: tuple | None = None,
        clients: int | None = None, hot_frac: float | None = None,
        mutation_rate: float | None = None, seed: int | None = None,
        n_shards: int = 4, fanout_reps: int = 3,
        replica_counts: tuple = (0, 1, 2, 4), replica_queries: int = 1500,
        quiet: bool = False, json_path: str | None = BENCH_JSON) -> dict:
    """Run the load harness; returns (and optionally writes) the bench dict.

    Defaults resolve through the ``ITR_LOAD_*`` environment; pass
    ``json_path=None`` to skip writing (the smoke path — the caller merges
    the dict into the smoke artifact instead).
    """
    duration = _env_float("ITR_LOAD_DURATION", 2.0) \
        if duration is None else float(duration)
    rates = _env_rates((100.0, 200.0, 400.0)) if rates is None else rates
    clients = int(_env_float("ITR_LOAD_CLIENTS", 4)) \
        if clients is None else int(clients)
    hot_frac = _env_float("ITR_LOAD_HOT", 0.5) \
        if hot_frac is None else float(hot_frac)
    mutation_rate = _env_float("ITR_LOAD_MUTATIONS", 50.0) \
        if mutation_rate is None else float(mutation_rate)
    seed = int(_env_float("ITR_LOAD_SEED", 0)) if seed is None else int(seed)

    ds = PAPER_DATASETS[dataset]() if scale is None \
        else PAPER_DATASETS[dataset](scale=scale)
    rng = np.random.default_rng(seed)
    svc = ShardedTripleService.build(
        ds.triples, ds.n_nodes, ds.n_preds, n_shards=n_shards,
        strategy="predicate_hash", rebalance_skew=None)
    bench: dict = {
        "dataset": dataset,
        "duration_s": duration,
        "clients": clients,
        "hot_fraction": hot_frac,
        "mutation_rate": mutation_rate,
        "n_shards": n_shards,
        "serve_threads": svc.serve_threads,
    }

    stop = threading.Event()
    mutator = _Mutator(svc, ds.triples, ds.n_nodes, ds.n_preds,
                       mutation_rate, stop, seed + 1)
    mutator.start()
    try:
        # saturation sweep: short open-loop windows at rising offered rates
        sweep = []
        for rate in rates:
            reqs = _pattern_stream(ds.triples, rng, hot_frac,
                                   max(1, int(rate * duration)))
            sweep.append(_open_loop(svc, reqs, rate, clients, rng))
            if not quiet:
                w = sweep[-1]
                print(f"offered {rate:.0f} qps: achieved "
                      f"{w['achieved_qps']:.0f} qps, p50 {w['p50_ms']:.2f}ms "
                      f"p95 {w['p95_ms']:.2f}ms p99 {w['p99_ms']:.2f}ms")
        cleared = [w for w in sweep
                   if w["achieved_qps"] >= 0.9 * w["offered_qps"]]
        bench["saturation"] = {
            "rates": [w["offered_qps"] for w in sweep],
            "achieved": [w["achieved_qps"] for w in sweep],
            "saturation_qps": cleared[-1]["achieved_qps"] if cleared
            else sweep[0]["achieved_qps"],
        }
        # the headline percentiles: the lowest (sub-saturation) rate window
        bench["latency"] = sweep[0]
    finally:
        stop.set()
        mutator.join(timeout=30)
    bench["mutation_ops"] = mutator.ops
    svc.close()

    bench["scatter_fanout"] = _scatter_fanout(
        ds.triples, ds.n_nodes, ds.n_preds, n_shards=n_shards,
        reps=fanout_reps, threads=resolve_serve_threads(None), quiet=quiet)
    bench["replica_scaling"] = _replica_scaling(
        ds.triples, ds.n_nodes, ds.n_preds, n_shards=n_shards,
        clients=clients, n_queries=replica_queries, reps=fanout_reps,
        counts=replica_counts, quiet=quiet)

    # dimensionless signals for the CI smoke gate (benchmarks.run --check):
    # achieved/offered collapses when the request plane stops keeping up,
    # fan-out and replica speedups collapse when parallel serving stops
    # helping (or breaks)
    lat = bench["latency"]
    bench["smoke_signals"] = {
        "achieved_vs_offered": lat["achieved_qps"] / lat["offered_qps"],
        "scatter_fanout_speedup": bench["scatter_fanout"]["speedup"],
        "replica_scaling_speedup": bench["replica_scaling"]["speedup"],
    }
    if not quiet:
        print(f"saturation: {bench['saturation']['saturation_qps']:.0f} qps "
              f"({bench['mutation_ops']} background mutation ops)")
    if json_path is not None:
        Path(json_path).write_text(json.dumps(bench, indent=2))
        if not quiet:
            print(f"wrote {json_path}")
    return bench


def run_smoke(quiet: bool = True) -> dict:
    """Seconds-long pass on a tiny graph: same code path end to end, no
    tracked JSON. The dict lands in the smoke artifact via benchmarks.run."""
    return run(scale=0.02, duration=0.4, rates=(60.0, 150.0), clients=2,
               hot_frac=0.5, mutation_rate=25.0, seed=0, n_shards=4,
               fanout_reps=2, replica_counts=(0, 1, 2), replica_queries=300,
               quiet=quiet, json_path=None)


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="tiny graph, sub-second windows, no JSON write")
    parser.add_argument("--json", default=BENCH_JSON,
                        help=f"output path (default {BENCH_JSON})")
    parser.add_argument("--quiet", action="store_true")
    args = parser.parse_args()
    if args.smoke:
        bench = run_smoke(quiet=args.quiet)
        print(json.dumps(bench["smoke_signals"], indent=2))
    else:
        run(quiet=args.quiet, json_path=args.json)
