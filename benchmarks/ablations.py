"""Design-decision ablations.

1. Paper §Handling loops: loop edges as extra rules (Figure 1 (c)/(e)) vs
   the paper's index-functions. Claim under test: "these extra rules do not
   improve compression, because the index-function ... also removes the
   duplicate parameters."
2. Beyond-paper: mfd selection by raw count (paper) vs by estimated unit
   savings (accounts for digram rank in the stop/pick decision).
"""
from __future__ import annotations

from repro.core import Hypergraph, LabelTable, RepairConfig, compress, encode
from repro.core.ablations import loop_rule_transform
from repro.data.synthetic import PAPER_DATASETS


def run_loop_rules(quiet=False):
    rows = []
    for name in ["ttt-win", "NotreDame", "CA-AstroPh"]:
        ds = PAPER_DATASETS[name]()
        table = LabelTable.terminals([2] * ds.n_preds)
        g = Hypergraph.from_triples(ds.triples, ds.n_nodes)
        grammar, _ = compress(g, table)
        base = encode(grammar).size_in_bytes()
        with_rules = encode(loop_rule_transform(grammar)).size_in_bytes()
        rows.append({"dataset": name, "index_fn_bytes": base,
                     "loop_rule_bytes": with_rules,
                     "loop_rules_win": with_rules < base})
        if not quiet:
            verdict = "worse-or-equal (paper confirmed)" if with_rules >= base else "BETTER (contradicts paper)"
            print(f"loops {name:<14} index-fn={base:>8}B  loop-rules={with_rules:>8}B  -> {verdict}")
    return rows


def run_selection(quiet=False):
    rows = []
    for name in ["geo-coordinates-en", "ttt-win"]:
        ds = PAPER_DATASETS[name]()
        table = LabelTable.terminals([2] * ds.n_preds)
        g = Hypergraph.from_triples(ds.triples, ds.n_nodes)
        out = {"dataset": name}
        for sel in ("count", "savings"):
            grammar, stats = compress(g, table, RepairConfig(selection=sel))
            out[sel] = encode(grammar).size_in_bytes()
            out[f"{sel}_rules"] = stats.rules_created
        out["savings_gain"] = 1 - out["savings"] / out["count"]
        rows.append(out)
        if not quiet:
            print(f"select {name:<20} count={out['count']}B savings={out['savings']}B "
                  f"(gain {out['savings_gain']:+.2%})")
    return rows


def run(quiet=False):
    return {"loop_rules": run_loop_rules(quiet), "selection": run_selection(quiet)}


if __name__ == "__main__":
    run()
