#!/usr/bin/env python
"""Run the full dry-run sweep (40 cells × 2 meshes) as parallel subprocesses.

Each cell runs in its own process (XLA device-count env is process-global),
writes results/dryrun/<arch>__<shape>__<mesh>.json, and logs to
results/dryrun/logs/. Usage: python scripts/run_dryrun_sweep.py [--workers N]
"""
import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.configs.registry import all_cells  # noqa: E402


def run_one(arch, shape, multi_pod, timeout=1800):
    mesh = "2pod" if multi_pod else "1pod"
    safe = arch.replace("/", "_").replace(".", "_")
    out = os.path.join(ROOT, "results", "dryrun", f"{safe}__{shape}__{mesh}.json")
    log = os.path.join(ROOT, "results", "dryrun", "logs", f"{safe}__{shape}__{mesh}.log")
    os.makedirs(os.path.dirname(log), exist_ok=True)
    if os.path.exists(out):
        with open(out) as fh:
            r = json.load(fh)
        if isinstance(r, dict) and r.get("ok"):
            return (arch, shape, mesh, "cached", 0.0)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    t0 = time.time()
    with open(log, "w") as lf:
        try:
            p = subprocess.run(cmd, stdout=lf, stderr=subprocess.STDOUT,
                               timeout=timeout, env=env, cwd=ROOT)
            status = "ok" if p.returncode == 0 else f"rc={p.returncode}"
        except subprocess.TimeoutExpired:
            status = "timeout"
    return (arch, shape, mesh, status, time.time() - t0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--mesh", choices=["1pod", "2pod", "both"], default="both")
    args = ap.parse_args()
    jobs = []
    for arch, shape in all_cells():
        if args.mesh in ("1pod", "both"):
            jobs.append((arch, shape, False))
        if args.mesh in ("2pod", "both"):
            jobs.append((arch, shape, True))
    # heaviest first so stragglers start early
    heavy = {"yi-34b": 0, "phi3.5-moe-42b-a6.6b": 1, "gemma2-9b": 2}
    jobs.sort(key=lambda j: heavy.get(j[0], 9))
    print(f"{len(jobs)} dry-run jobs, {args.workers} workers")
    results = []
    with ThreadPoolExecutor(args.workers) as ex:
        futs = {ex.submit(run_one, *j): j for j in jobs}
        for fut in as_completed(futs):
            r = fut.result()
            results.append(r)
            print(f"[{len(results)}/{len(jobs)}] {r[0]} × {r[1]} × {r[2]}: {r[3]} ({r[4]:.0f}s)", flush=True)
    bad = [r for r in results if r[3] not in ("ok", "cached")]
    print(f"\ndone: {len(results) - len(bad)}/{len(results)} ok")
    for r in bad:
        print("FAILED:", r)
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
