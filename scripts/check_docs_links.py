#!/usr/bin/env python
"""Docs dead-link gate: every relative markdown link and every
repo-path reference in README.md, ROADMAP.md, and docs/*.md must point
at a file or directory that actually exists.

Run from the repo root (CI does): exits 1 listing each dead link.
Two classes of reference are checked:

* Markdown links ``[text](target)`` whose target is not an absolute URL
  (``http(s)://``, ``mailto:``) — resolved relative to the file that
  contains them, ``#anchor`` suffixes stripped (a pure ``#anchor`` link
  is same-file and always fine).
* Backticked repo paths like ``src/repro/persist/wal.py`` or
  ``benchmarks/serving_load.py`` — conservatively, only tokens rooted at
  a known top-level source directory, so prose like ``state/`` or
  ``snap_NNNNNN/`` never false-positives. A trailing ``::name`` (pytest
  node id) is ignored.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `docs/FILE.md`, `src/pkg/mod.py`, `tests/test_x.py::test_y`, `.github/...`
PATH_RE = re.compile(
    r"`((?:src|docs|tests|benchmarks|scripts|examples|roofline|\.github)"
    r"/[\w./\-]+)(?:::[\w\[\]./\-]+)?`"
)
EXTERNAL = ("http://", "https://", "mailto:")


def doc_files(root: Path) -> list[Path]:
    files = [root / "README.md", root / "ROADMAP.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def dead_links(root: Path) -> list[str]:
    """Human-readable ``file: target`` entries for every dead reference."""
    errors: list[str] = []
    for doc in doc_files(root):
        rel = doc.relative_to(root)
        text = doc.read_text()
        for target in LINK_RE.findall(text):
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (doc.parent / path).exists():
                errors.append(f"{rel}: dead link ({target})")
        for path in PATH_RE.findall(text):
            if not (root / path.rstrip("/")).exists():
                errors.append(f"{rel}: missing path (`{path}`)")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    errors = dead_links(root)
    for err in errors:
        print(f"links gate: {err}", file=sys.stderr)
    print(f"links gate: {len(doc_files(root))} doc file(s) scanned, "
          f"{len(errors)} dead reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
