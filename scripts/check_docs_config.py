#!/usr/bin/env python
"""Docs-consistency gate: every ITR_* env var referenced in src/,
benchmarks/, or scripts/ — or set by a workflow under
.github/workflows/ — must be documented in docs/CONFIG.md.

Run from the repo root (CI does): exits 1 listing any undocumented
variable. Documented-but-unreferenced variables are reported as warnings
only — a knob can legitimately be documented ahead of a staged rollout,
but an undocumented live knob is exactly the rot this gate exists for.
The workflow scan closes the test-lane gap: a budget knob only a CI lane
sets (and only tests read) is still part of the operational surface.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ENV_RE = re.compile(r"\bITR_[A-Z0-9_]+\b")

# every tree whose python sources can read a knob (tests are exempt:
# test-only tuning vars are documented next to the lane that sets them)
SCAN_DIRS = ("src", "benchmarks", "scripts")


def referenced_vars(*roots: Path) -> dict[str, list[str]]:
    """ITR_* names -> files referencing them, over all python sources."""
    refs: dict[str, list[str]] = {}
    for root in roots:
        for path in sorted(root.rglob("*.py")):
            for name in set(ENV_RE.findall(path.read_text())):
                refs.setdefault(name, []).append(str(path))
    return refs


def workflow_vars(root: Path) -> dict[str, list[str]]:
    """ITR_* names -> workflow files referencing them. A knob a CI lane
    sets is live even when no python source under SCAN_DIRS reads it
    (test-lane budgets like the nightly oracle knobs) — leaving it out of
    CONFIG.md would hide a variable operators actually tune."""
    refs: dict[str, list[str]] = {}
    workflows = root / ".github" / "workflows"
    if not workflows.is_dir():
        return refs
    for pattern in ("*.yml", "*.yaml"):
        for path in sorted(workflows.glob(pattern)):
            for name in set(ENV_RE.findall(path.read_text())):
                refs.setdefault(name, []).append(str(path))
    return refs


def documented_vars(config_md: Path) -> set[str]:
    return set(ENV_RE.findall(config_md.read_text()))


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    config_md = root / "docs" / "CONFIG.md"
    if not config_md.exists():
        print(f"docs gate: {config_md} missing", file=sys.stderr)
        return 1
    refs = referenced_vars(*(root / d for d in SCAN_DIRS))
    for name, files in workflow_vars(root).items():
        refs.setdefault(name, []).extend(files)
    documented = documented_vars(config_md)
    missing = sorted(set(refs) - documented)
    for name in missing:
        print(f"docs gate: {name} referenced in {', '.join(refs[name])} "
              f"but absent from docs/CONFIG.md", file=sys.stderr)
    for name in sorted(documented - set(refs)):
        print(f"docs gate: warning: {name} documented but no longer "
              f"referenced under {'/'.join(SCAN_DIRS)} or "
              f".github/workflows")
    print(f"docs gate: {len(refs)} env var(s) referenced, "
          f"{len(missing)} undocumented")
    return 1 if missing else 0


if __name__ == "__main__":
    sys.exit(main())
